"""Autotuner smoke sweep: best-vs-default gates + roofline-model-gated rows.

The paper's claim structure is "the design-space search finds a better
point than the naive configuration, and the resource model predicts the
measured latency".  This module turns both halves into gated BENCH rows
over a small smoke grid (kept small — CI runs it before tier-1):

* ``autotune.best_vs_default_{case}`` — measured default-knob time over
  measured best-knob time, **hard-gated >= 1.0** for every (geometry,
  backend) in the grid.  The sweep grid always contains the default
  point and best = min over the grid, so a value below 1.0 can only
  mean the sweep harness itself is broken (timed different programs,
  lost the default point) — exactly what the gate is for;
* ``autotune.model_gate_{case}`` — the fitted roofline model's predicted
  time vs the measured default time, with the margin stated in the row
  (``gate=model`` rows carry ``predicted=``/``measured=``/``margin=``;
  ``benchmarks/run.py`` enforces that schema).  Interpret-mode CPU
  timings are noisy, so the ok-flag margin is generous (5x) and only a
  catastrophic disagreement (10x) raises — the row's job in CI is to
  catch the model going wild, the tight statistics belong to a real
  device run via ``launch/tune.py``;
* ``autotune.model_fit_medianerr`` — the fit's own median relative
  error over the smoke records (soft-gated: ok iff <= 1.0, i.e. the
  model is within 2x of reality on at least half the records).
"""

from __future__ import annotations

from repro.autotune.model import attach_costs, fit_roofline
from repro.autotune.sweep import (
    best_record,
    default_record,
    run_sweep,
    smoke_cases,
)

#: soft ok-flag margin for timing model gates and the hard catastrophic
#: ceiling.  CPU interpret mode is correctness-grade, not perf-grade: the
#: interpreted wavefront's cost scales with grid *steps* rather than
#: FLOPs, so the long-T fused_stack cases sit ~4x off a roofline fitted
#: jointly with the step cases — the margin must clear that structural
#: gap while the ceiling still catches the model losing contact entirely
MODEL_GATE_MARGIN = 5.0
MODEL_GATE_CEILING = 10.0

#: the smoke grid (shared with ``launch/tune.py --smoke``)
SMOKE_CASES = smoke_cases()


def best_vs_default_rows(case, records) -> list[tuple]:
    best = best_record(records)
    default = default_record(records)
    ratio = default["us"] / best["us"]
    ok = ratio >= 1.0
    print(f"{case.tag:<42} default {default['us']:8.1f}us  "
          f"best {best['us']:8.1f}us [{best['point']}]  {ratio:.3f}x "
          f"({'OK' if ok else 'REGRESSION'})")
    row = (
        f"autotune.best_vs_default_{case.tag}", best["us"],
        f"default_us={default['us']:.1f}|best={best['point']}"
        f"|ratio={ratio:.3f}|ok={int(ok)}",
    )
    if not ok:
        raise RuntimeError(
            f"autotune sweep for {case.tag} found best {best['us']:.1f}us "
            f"SLOWER than the default {default['us']:.1f}us (ratio "
            f"{ratio:.3f} < 1.0) — impossible for a grid that contains the "
            "default point; the sweep harness is measuring inconsistently"
        )
    return [row]


def model_gate_row(case, fit, record) -> tuple:
    """Predicted-vs-measured row for one record, margin stated inline."""
    predicted = fit.predict_us(
        record["costs"]["flops"], record["costs"]["bytes"]
    )
    measured = record["us"]
    hi, lo = max(predicted, measured), max(min(predicted, measured), 1e-9)
    ok = hi / lo <= MODEL_GATE_MARGIN
    print(f"{case.tag:<42} model {predicted:8.1f}us  "
          f"measured {measured:8.1f}us ({'OK' if ok else 'off-model'})")
    if hi / lo > MODEL_GATE_CEILING:
        raise RuntimeError(
            f"roofline model predicts {predicted:.1f}us for {case.tag} but "
            f"{measured:.1f}us was measured (> {MODEL_GATE_CEILING}x apart) "
            "— the perf model has lost contact with the machine; re-fit "
            "with launch/tune.py or fix the cost extraction"
        )
    return (
        f"autotune.model_gate_{case.tag}", measured,
        f"predicted={predicted:.1f}|measured={measured:.1f}"
        f"|margin={MODEL_GATE_MARGIN}|gate=model|ok={int(ok)}",
    )


def run(k: int = 3, reps: int = 3, max_points: int = 6) -> list[tuple]:
    print("\n== autotune: smoke sweep, best-vs-default + model gates ==")
    rows: list[tuple] = []
    fit_records = []
    sweeps = []
    for case in SMOKE_CASES:
        records = run_sweep(case, k=k, reps=reps, max_points=max_points)
        sweeps.append((case, records))
        rows += best_vs_default_rows(case, records)
        # fit on default + best per case: enough spread to identify the
        # three coefficients without compiling every grid point twice
        fit_records += attach_costs(
            [default_record(records), best_record(records)]
        )
    fit = fit_roofline(fit_records)
    print(fit.describe())
    by_tag = {r["case"]: r for r in fit_records if not r["knobs"]}
    for case, _ in sweeps:
        rows.append(model_gate_row(case, fit, by_tag[case.tag]))
    fit_ok = fit.median_rel_err <= 1.0
    rows.append((
        "autotune.model_fit_medianerr", fit.median_rel_err * 100.0,
        f"median_rel_err={fit.median_rel_err:.3f}"
        f"|max_rel_err={fit.max_rel_err:.3f}|n={fit.n_records}"
        f"|ok={int(fit_ok)}",
    ))
    return rows


if __name__ == "__main__":
    run()
