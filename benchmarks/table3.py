"""Paper Table III: batch-1 inference latency — CPU measured here, FPGA/GPU
quoted from the paper, TPU modelled from the fused-kernel structure.

We measure OUR implementations on this host CPU (the paper's CPU row was an
Intel E2620 at 39.7 ms; theirs ran TensorFlow, ours is jit-compiled JAX, so
our CPU row is much faster — the comparison point is the *relative* win of
the split/fused structure at batch 1, which is the paper's argument).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.autoencoder import (
    AutoencoderConfig,
    autoencoder_forward,
    init_autoencoder,
)

PAPER = {"cpu_E2620_ms": 39.7, "gpu_titanx_ms": 32.1, "fpga_u250_us": 0.40}


def _time(f, *args, iters=50) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[tuple]:
    cfg_n = AutoencoderConfig(hidden=(32, 8, 8, 32), timesteps=100, impl="naive")
    cfg_s = AutoencoderConfig(hidden=(32, 8, 8, 32), timesteps=100, impl="split")
    params = init_autoencoder(jax.random.PRNGKey(0), cfg_n)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 100, 1))

    naive = jax.jit(lambda p, x: autoencoder_forward(p, x, cfg_n))
    split = jax.jit(lambda p, x: autoencoder_forward(p, x, cfg_s))

    t_naive = _time(naive, params, x)
    t_split = _time(split, params, x)

    print("\n== Table III: batch-1 nominal-AE inference latency ==")
    print(f"paper CPU (E2620, TF):        {PAPER['cpu_E2620_ms']*1000:>10.1f} us")
    print(f"paper GPU (TITAN X):          {PAPER['gpu_titanx_ms']*1000:>10.1f} us")
    print(f"paper FPGA (U250, balanced):  {PAPER['fpga_u250_us']:>10.2f} us")
    print(f"this host CPU, naive LSTM:    {t_naive:>10.1f} us")
    print(f"this host CPU, split mvm_x:   {t_split:>10.1f} us "
          f"({t_naive / t_split:.2f}x vs naive)")
    return [
        ("table3.cpu_naive", t_naive, f"paper_cpu_us={PAPER['cpu_E2620_ms']*1000}"),
        ("table3.cpu_split", t_split, f"speedup_vs_naive={t_naive/t_split:.2f}"),
        ("table3.paper_fpga", PAPER["fpga_u250_us"], "reference"),
    ]


if __name__ == "__main__":
    run()
