"""Quantized packed-weight fused stack: bytes, latency, AUC parity, serving.

The paper's headline resource win is precision (Sec. IV-A: 16-bit fixed
weights + 32-bit cell cut DSPs up to 42% at the same II).  The TPU analogue
is the packed stack's weight *storage* dtype: int8/bf16 codes stay
VMEM-resident (per-layer dequant scales in SMEM) while compute and the cell
carry stay at the config dtype / fp32.  Rows:

* ``quant.packed_bytes_{fp32,bf16,int8}`` — VMEM bytes of the GW nominal
  autoencoder's packed segments, model-gated: the measured pack must match
  ``autotune.model.predict_pack_bytes``'s closed-form prediction within a
  stated margin (the old ad-hoc ">= 2x fp32/int8 ratio" gate is now the
  informational ``quant.packed_bytes_ratio`` row — the model gate subsumes
  it, since matching the analytic layout at every dtype implies the ratio);
* ``quant.gw_ae_fused_{wd}_us`` — fused autoencoder forward latency per
  weight dtype (interpret-mode on CPU: correctness-grade);
* ``quant.auc_fused_{wd}`` — the paper's "negligible AUC change" claim
  reproduced end-to-end on the fused path (trained small model, signal vs
  background AUC per weight dtype);
* ``quant.stream_packs_steady`` — quantized streaming serve keeps the
  pre-packed contract: zero pack traces in steady state (gated).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import pipeline
from repro.core.autoencoder import (
    AutoencoderConfig,
    autoencoder_forward,
    decoder_layers,
    encoder_layers,
    init_autoencoder,
)
from repro.core.quant import WEIGHT_DTYPES
from repro.kernels.lstm_stack.ops import pack_stack

#: model-gate margin: the closed-form pack-bytes prediction mirrors the
#: layout exactly, so any drift beyond rounding means the pack layout and
#: the model disagree — one of them changed without the other
PACK_BYTES_MARGIN = 0.02


def packed_bytes_rows(cfg: AutoencoderConfig, params) -> list[tuple]:
    from repro.autotune.model import predict_pack_bytes

    rows, by_dtype = [], {}
    enc_p, enc_cfgs = encoder_layers(params, cfg)
    dec_p, dec_cfgs = decoder_layers(params, cfg)
    for wd in WEIGHT_DTYPES:
        nbytes = (
            pack_stack(enc_p, enc_cfgs, weight_dtype=wd).packed_bytes
            + pack_stack(dec_p, dec_cfgs, weight_dtype=wd).packed_bytes
        )
        predicted = (
            predict_pack_bytes(enc_cfgs, wd) + predict_pack_bytes(dec_cfgs, wd)
        )
        by_dtype[wd] = nbytes
        ok = abs(nbytes - predicted) <= PACK_BYTES_MARGIN * predicted
        print(f"packed stacks [{wd:>4}]: {nbytes / 1024:8.1f} KiB "
              f"(model: {predicted / 1024:8.1f} KiB, "
              f"{'OK' if ok else 'REGRESSION'})")
        rows.append((
            f"quant.packed_bytes_{wd}", 0.0,
            f"predicted={predicted}|measured={nbytes}"
            f"|margin={PACK_BYTES_MARGIN}|gate=model|ok={int(ok)}",
        ))
        if not ok:
            raise RuntimeError(
                f"{wd} pack occupies {nbytes} B but the layout model "
                f"predicts {predicted} B (margin {PACK_BYTES_MARGIN:.0%}) — "
                "the pack layout and autotune.model.predict_pack_bytes have "
                "diverged; fix whichever changed without the other"
            )
    ratio = by_dtype["fp32"] / by_dtype["int8"]
    print(f"fp32/int8 packed-bytes ratio: {ratio:.2f}x (informational; the "
          "per-dtype model gates above subsume it)")
    rows.append(("quant.packed_bytes_ratio", 0.0, f"ratio={ratio:.3f}"))
    return rows


def latency_rows(cfg: AutoencoderConfig, params) -> list[tuple]:
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(3), (256, cfg.timesteps, 1))
    for wd in WEIGHT_DTYPES:
        c = dataclasses.replace(cfg, impl="fused_stack", weight_dtype=wd)
        f = jax.jit(lambda p, x, c=c: autoencoder_forward(p, x, c))
        jax.block_until_ready(f(params, x))
        n_iter = 5
        t0 = time.perf_counter()
        for _ in range(n_iter):
            out = f(params, x)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / n_iter * 1e6
        print(f"gw_nominal_ae[fused {wd:>4}] (B256,T{cfg.timesteps}): "
              f"{us:10.0f} us")
        rows.append((f"quant.gw_ae_fused_{wd}_us", us, ""))
    return rows


def auc_rows(steps: int) -> list[tuple]:
    from benchmarks.fig9_auc import evaluate_auc, train_autoencoder

    cfg = AutoencoderConfig(hidden=(9, 9), latent_boundary=1, timesteps=100)
    params, losses, ds = train_autoencoder(cfg, steps=steps)
    rows, auc = [], {}
    for wd in WEIGHT_DTYPES:
        c = dataclasses.replace(cfg, impl="fused_stack", weight_dtype=wd)
        auc[wd] = evaluate_auc(params, c, ds)
        delta = auc[wd] - auc["fp32"]
        print(f"AUC fused {wd:>4}: {auc[wd]:.3f}  (delta {delta:+.4f})")
        rows.append((f"quant.auc_fused_{wd}", 0.0,
                     f"{auc[wd]:.3f}|delta={delta:+.4f}"))
    # heterogeneous storage through the mixed backend: the paper's
    # mixed-precision axis (narrow early layers, full-precision late) must
    # land between the homogeneous ends, and both ends routed through the
    # mixed chain must agree with the fused rows above
    n = len(cfg.hidden)
    for tag, wds in (
        ("int8_early", ("int8",) + ("fp32",) * (n - 1)),
        ("all_int8", ("int8",) * n),
        ("all_fp32", ("fp32",) * n),
    ):
        c = dataclasses.replace(cfg, impl="mixed", weight_dtypes=wds)
        a = evaluate_auc(params, c, ds)
        delta = a - auc["fp32"]
        print(f"AUC mixed {'+'.join(wds):>10}: {a:.3f}  (delta {delta:+.4f})")
        rows.append((f"quant.auc_mixed_{tag}", 0.0,
                     f"{a:.3f}|delta={delta:+.4f}"))
    # in-kernel activation fake-quant on the fp32 fused path (paper: 16-bit
    # activations with a 32-bit cell carry; 8 bits shows the cliff)
    for bits in (16, 8):
        c = dataclasses.replace(cfg, impl="fused_stack", act_bits=bits)
        a = evaluate_auc(params, c, ds)
        delta = a - auc["fp32"]
        print(f"AUC act_bits={bits:2d} (fp32): {a:.3f}  (delta {delta:+.4f})")
        rows.append((f"quant.auc_mixed_act{bits}", 0.0,
                     f"{a:.3f}|delta={delta:+.4f}"))
    print("(paper: quantization effect on AUC negligible)")
    return rows


def stream_steady_row(cfg: AutoencoderConfig) -> list[tuple]:
    from repro.serve.engine import StreamingAnomalyEngine

    cfg8 = dataclasses.replace(
        cfg, hidden=(9, 9), latent_boundary=1, weight_dtype="int8"
    )
    params = init_autoencoder(jax.random.PRNGKey(4), cfg8)
    eng = StreamingAnomalyEngine(params, cfg8, batch=1, window=cfg8.timesteps)
    w = np.random.default_rng(0).standard_normal(
        (1, cfg8.timesteps, 1)
    ).astype(np.float32)
    eng.push(w)  # compile
    before = pipeline.PACK_TRACE_COUNT
    for _ in range(3):
        eng.push(w)
    steady = pipeline.PACK_TRACE_COUNT - before
    ok = steady == 0
    print(f"int8 streaming pack traces in steady state: {steady} "
          f"({'OK' if ok else 'REGRESSION'})")
    if not ok:
        raise RuntimeError(
            f"int8 steady-state streaming re-traced pack_lstm_stack "
            f"{steady}x — quantized serving lost the pre-packed contract"
        )
    return [("quant.stream_packs_steady", 0.0, f"packs_steady={steady}|ok=1")]


def run(steps: int = 120) -> list[tuple]:
    print("\n== quant: packed-weight fused stack (fp32 / bf16 / int8) ==")
    cfg = AutoencoderConfig(hidden=(32, 8, 8, 32), timesteps=100)
    params = init_autoencoder(jax.random.PRNGKey(2), cfg)
    rows = packed_bytes_rows(cfg, params)
    rows += latency_rows(cfg, params)
    rows += stream_steady_row(cfg)
    print(f"\n== quant: fig9-style AUC parity on the fused path "
          f"({steps}-step training) ==")
    rows += auc_rows(steps)
    return rows


if __name__ == "__main__":
    run()
