"""§Roofline: the 40-cell table from the dry-run artifacts.

Per (arch x shape x mesh) cell, from runs/dryrun/*.json:

    compute term    = dot_FLOPs_per_device / PEAK            [s]
    memory term     = HBM_bytes_per_device / HBM_BW          [s]
    collective term = collective_bytes_per_device / LINK_BW  [s]

dot_FLOPs come from the scan-corrected HLO parse (XLA's cost_analysis counts
while bodies once — verified empirically; see EXPERIMENTS.md).  HBM bytes
are modelled as ``args + out + temp_tpu_adjusted`` (weights/cache/opt read
once, outputs written once, transients written+read but largely VMEM-
resident on TPU — counted once as the middle estimate); temp is adjusted by
removing the CPU-backend bf16->f32 convert shadows that do not exist on TPU.
MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (prefill/decode).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI —
carried by ``repro.autotune.model.TPU_V5E``; the three time terms are
computed by ``roofline_terms_from_counts`` (one implementation shared with
the autotuner's fitted perf model), this module only assembles the byte
counts and the table.
"""

from __future__ import annotations

import glob
import json
import os
from pathlib import Path

from repro.autotune.model import TPU_V5E, roofline_terms_from_counts

# legacy aliases — the datasheet constants live on the HardwareModel now
PEAK = TPU_V5E.peak_flops
HBM_BW = TPU_V5E.hbm_bw
LINK_BW = TPU_V5E.link_bw

HBM_PER_CHIP = TPU_V5E.hbm_bytes

#: model's bound names -> this table's historical column vocabulary
_BOUND_NAMES = {"compute": "compute", "hbm": "memory", "link": "collective"}


def load_cells(run_dir: str = "runs/dryrun") -> list[dict]:
    """Parsed dry-run cells.  A missing or empty run dir raises — an empty
    table looks exactly like a healthy all-skipped run, so silence here
    has previously hidden a wrong --run-dir for a whole CI cycle."""
    if not os.path.isdir(run_dir):
        raise FileNotFoundError(
            f"roofline run dir {run_dir!r} does not exist; generate cells "
            "with the dry-run driver (see EXPERIMENTS.md) or pass the "
            "directory that holds them"
        )
    files = sorted(glob.glob(f"{run_dir}/*.json"))
    if not files:
        raise FileNotFoundError(
            f"roofline run dir {run_dir!r} contains no *.json cells; an "
            "empty table would render as success — refusing"
        )
    return [json.loads(Path(f).read_text()) for f in files]


def roofline_terms(rec: dict, shape_meta: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    mem = rec["memory"]
    # CPU-backend f32 shadow copies of bf16 stacks (see analysis/hlo.py):
    # the per-op sum over-counts reused buffers, so clamp the subtraction to
    # 80% of temp — a deliberately conservative "TPU-adjusted" estimate
    # (documented in EXPERIMENTS.md §Dry-run).
    raw_temp = mem["temp_bytes"] or 0
    artifact = min(rec.get("cpu_convert_artifact_bytes", 0), 0.8 * raw_temp)
    temp_adj = max(raw_temp - artifact, 0)
    hbm_bytes = (mem["argument_bytes"] or 0) + (mem["output_bytes"] or 0) + temp_adj
    coll = sum(rec.get("collective_bytes", {}).values())
    terms = roofline_terms_from_counts(
        rec["hlo_dot_flops"], hbm_bytes, coll, hw=TPU_V5E
    )
    t_c = terms["t_compute_us"] * 1e-6
    t_m = terms["t_hbm_us"] * 1e-6
    t_l = terms["t_link_us"] * 1e-6
    dominant = _BOUND_NAMES[terms["bound"]]
    # model flops (global)
    kind = shape_meta["kind"]
    bsz, seq = shape_meta["global_batch"], shape_meta["seq_len"]
    n_act = rec["n_active_params"]
    if kind == "train":
        model_flops = 6.0 * n_act * bsz * seq
    elif kind == "prefill":
        model_flops = 2.0 * n_act * bsz * seq
    else:  # decode: one token per sequence
        model_flops = 2.0 * n_act * bsz
    hlo_global = rec["hlo_dot_flops"] * rec["chips"]
    return {
        "cell": rec["cell"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_l,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / hlo_global if hlo_global else float("nan"),
        "roofline_fraction": t_c / max(t_c, t_m, t_l),
        "hbm_gib": ((mem["argument_bytes"] or 0) + temp_adj) / 2**30,
        "fits_16g": ((mem["argument_bytes"] or 0) + temp_adj) < HBM_PER_CHIP,
    }


def run(run_dir: str = "runs/dryrun") -> list[tuple]:
    from repro.configs.base import SHAPES

    cells = load_cells(run_dir)
    rows, out = [], []
    print("\n== §Roofline: per-cell terms (seconds/step, per chip) ==")
    hdr = (f"{'cell':<52} {'compute':>10} {'memory':>10} {'collect':>10} "
           f"{'dom':>9} {'useful':>7} {'RLfrac':>7} {'GiB':>6} fit")
    print(hdr)
    for rec in cells:
        if rec["status"] == "skipped":
            print(f"{rec['cell']:<52} SKIPPED: {rec['reason'][:60]}")
            out.append((f"roofline.{rec['cell']}", 0.0, "skipped"))
            continue
        shape = SHAPES[rec["shape"]]
        t = roofline_terms(rec, {"kind": shape.kind,
                                 "global_batch": shape.global_batch,
                                 "seq_len": shape.seq_len})
        if t is None:
            print(f"{rec['cell']:<52} FAILED")
            continue
        print(f"{t['cell']:<52} {t['compute_s']:>10.3e} {t['memory_s']:>10.3e} "
              f"{t['collective_s']:>10.3e} {t['dominant']:>9} "
              f"{t['useful_ratio']:>7.2f} {t['roofline_fraction']:>7.2f} "
              f"{t['hbm_gib']:>6.1f} {'Y' if t['fits_16g'] else 'N'}")
        rows.append(t)
        out.append((f"roofline.{t['cell']}", 0.0,
                    f"dom={t['dominant']}|frac={t['roofline_fraction']:.2f}"))

    # summary: worst roofline fraction / most collective-bound (single-pod)
    pod = [r for r in rows if "pod_16x16" in r["cell"] and "multipod" not in r["cell"]]
    if pod:
        worst = min(pod, key=lambda r: r["roofline_fraction"])
        coll = max(pod, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-30))
        print(f"\nworst roofline fraction: {worst['cell']} ({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:   {coll['cell']} "
              f"(coll/compute = {coll['collective_s']/max(coll['compute_s'],1e-30):.2f})")
    return out


if __name__ == "__main__":
    run()
