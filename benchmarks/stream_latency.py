"""Streaming B=1 serving latency vs one-shot batch scoring.

The paper's deployment unit is a batch-1 strain window arriving
continuously (Table III's latency target).  This benchmark compares, on
the same pre-packed fused stack:

* ``StreamingAnomalyEngine`` full-window push (one encoder kernel call +
  decode per window, persistent state, donated buffers);
* ``StreamingAnomalyEngine`` chunked push (window split into 4 chunks —
  the pipeline never re-fills between chunks);
* ``AnomalyStreamEngine`` one-shot scoring at B=1 and B=8 (per-window
  amortized).

It also asserts the serving-cache contract: ``pack_lstm_stack`` must not
be re-traced by steady-state scoring — packing happens exactly once per
params identity, at engine init (the ``packs`` field of the acceptance
row; ``ok=1`` means zero pack growth across the timed loop).

Interpret-mode timings on CPU are correctness-grade only; on a TPU host
the same code path runs the compiled wavefront kernel.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.gw import GW_MODELS
from repro.core import pipeline
from repro.core.autoencoder import init_autoencoder
from repro.serve.engine import AnomalyStreamEngine, StreamingAnomalyEngine


def _time(fn, n_iter: int = 10) -> float:
    fn()  # warm up (compile)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()  # engines sync internally (scores come back as numpy)
    return (time.perf_counter() - t0) / n_iter * 1e6


def run() -> list[tuple]:
    rows = []
    cfg = GW_MODELS["gw_small"]
    t_len = cfg.timesteps
    params = init_autoencoder(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1, t_len, 1)).astype(np.float32)
    w8 = rng.standard_normal((8, t_len, 1)).astype(np.float32)

    print("\n== serving: streaming B=1 vs one-shot batch (gw_small, "
          f"T={t_len}) ==")

    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    packs_at_init = pipeline.PACK_TRACE_COUNT

    us_window = _time(lambda: eng.push(w1))
    print(f"streaming push, full window : {us_window:10.0f} us/window")
    rows.append(("bench.stream_b1_window_us", us_window, ""))

    chunk = max(t_len // 4, 1)

    def push_chunked():
        out = []
        for pos in range(0, t_len, chunk):
            out += eng.push(w1[:, pos : pos + chunk])
        return out[0]

    us_chunked = _time(push_chunked)
    print(f"streaming push, 4 chunks    : {us_chunked:10.0f} us/window")
    rows.append(("bench.stream_b1_chunk_us", us_chunked, f"chunk={chunk}"))

    batch_eng = AnomalyStreamEngine(params, cfg)
    us_b1 = _time(lambda: batch_eng.score(w1))
    us_b8 = _time(lambda: batch_eng.score(w8)) / 8
    print(f"one-shot score, B=1         : {us_b1:10.0f} us/window")
    print(f"one-shot score, B=8         : {us_b8:10.0f} us/window (amortized)")
    rows.append(("bench.batch_b1_us", us_b1, ""))
    rows.append(("bench.batch_b8_us", us_b8, "per-window"))

    pack_growth = pipeline.PACK_TRACE_COUNT - packs_at_init
    # the one-shot engine legitimately traces its pack once per jit trace
    # (w1 and w8 shapes); the STREAMING loops must contribute zero.  Re-run
    # a streaming window now that every path is compiled and assert flat.
    before = pipeline.PACK_TRACE_COUNT
    for _ in range(3):
        eng.push(w1)
        eng.score(w1)
    steady_growth = pipeline.PACK_TRACE_COUNT - before
    ok = steady_growth == 0
    ratio = us_window / us_b1
    print(f"streaming vs one-shot B=1: {ratio:.2f}x; pack traces in "
          f"steady state: {steady_growth} ({'OK' if ok else 'REGRESSION'})")
    rows.append((
        "bench.stream_b1_vs_batch", us_window,
        f"ratio={ratio:.3f}|packs_steady={steady_growth}|"
        f"packs_timed={pack_growth}|ok={int(ok)}",
    ))
    if not ok:  # a hard gate, not just a row: CI's bench run must fail
        raise RuntimeError(
            f"steady-state scoring re-traced pack_lstm_stack "
            f"{steady_growth}x — the pre-packed serve contract regressed"
        )
    return rows


if __name__ == "__main__":
    run()
