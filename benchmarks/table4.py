"""Paper Table IV: latency vs prior FPGA LSTM designs (latency model).

The paper reports 0.343 us (single 32-unit layer) and 0.867 us (the nominal
4-layer autoencoder) at 300 MHz.  We reproduce both from the analytic
latency model (Eq. 1 + the Fig. 7 wavefront with the encoder->decoder sync
point) and report the model error; the prior-work rows are quoted.
"""

from __future__ import annotations

from repro.core.balance import table2_designs
from repro.core.ii_model import (
    U250,
    DesignPoint,
    LstmLayerDims,
    LstmModelDims,
    ReuseFactors,
)

PRIOR = {
    "lee2018_kintex7_us": 4.27,
    "rao2020_ku115_us": 1.35,
    "this_single_layer_us": 0.343,
    "this_four_layer_us": 0.867,
}


def run() -> list[tuple]:
    single = LstmModelDims(layers=(LstmLayerDims(lx=1, lh=32),))
    d1 = DesignPoint(model=single, reuse=(ReuseFactors(r_x=9, r_h=1),),
                     constants=U250, timesteps=8)
    lat1 = d1.latency_us(300.0)
    d4 = table2_designs()["U2"]
    lat4 = d4.latency_us(300.0)

    print("\n== Table IV: latency vs prior FPGA designs ==")
    print(f"[28] 2018 Kintex7 (1 layer):   {PRIOR['lee2018_kintex7_us']:.3f} us")
    print(f"[27] 2020 KU115  (1 layer):    {PRIOR['rao2020_ku115_us']:.3f} us")
    print(f"this work (1 layer) paper:     {PRIOR['this_single_layer_us']:.3f} us"
          f" | model: {lat1:.3f} us")
    print(f"this work (4 layers) paper:    {PRIOR['this_four_layer_us']:.3f} us"
          f" | model: {lat4:.3f} us (wavefront + enc->dec sync)")
    print(f"speedup vs [28]: {PRIOR['lee2018_kintex7_us']/PRIOR['this_single_layer_us']:.1f}x"
          f" (paper: 12.4x); vs [27]: {PRIOR['rao2020_ku115_us']/PRIOR['this_single_layer_us']:.1f}x"
          f" (paper: 3.9x)")
    return [
        ("table4.single_layer_model_us", lat1, f"paper={PRIOR['this_single_layer_us']}"),
        ("table4.four_layer_model_us", lat4, f"paper={PRIOR['this_four_layer_us']}"),
    ]


if __name__ == "__main__":
    run()
