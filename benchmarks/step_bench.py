"""Low-latency step path rows: streamed-vs-batch gap, kernel T=1 latency,
multi-stream coalescing.

The PR 5 serving claims, as ``step.*`` rows merged into the shared
``BENCH_kernels.json`` artifact (``make bench-step``):

* ``step.stream_b1_vs_batch`` — a B=1 window pushed through the
  ``fused_step`` engine (step kernel + bound jitted step + jit-cached
  state reset) vs the same window scored one-shot at B=1.  Same
  methodology as the pre-step baseline ``bench.stream_b1_vs_batch``
  (full-window push), which measured **6.99x**; **hard-gated at <= 3.5**.
  ``step.stream_b1_chunk_us`` reports the 4-chunk streamed variant
  (baseline ~8x) alongside.
* ``step.kernel_t1_us`` / ``step.kernel_fallback_t1_us`` — the step kernel
  vs the wavefront kernel on a single T=1 sample (the paper's
  initiation-interval regime): no out-of-kernel mvm_x, no (T, B, 4W) HBM
  round-trip, one grid step instead of T+L-1.
* ``step.push_many8_vs_sequential`` — 8 independent streams advanced by
  ONE coalesced B=8 step call per chunk vs 8 sequential B=1 push loops;
  **hard-gated on bit-equality** of every emitted score (the coalescer
  must be free: same math, one dispatch).

Interpret-mode timings on CPU are correctness-grade; on a TPU host the
same rows time the compiled kernels.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gw import GW_MODELS
from repro.core.autoencoder import init_autoencoder
from repro.serve.engine import AnomalyStreamEngine, StreamingAnomalyEngine

#: streamed chunk length: under the default plan chunk_len (32), so every
#: push rides the step kernel; 4 chunks fill the gw_small window
CHUNK = 25


def _time(fn, n_iter: int = 10) -> float:
    fn()  # warm up (compile)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()  # engines sync internally (scores come back as numpy)
    return (time.perf_counter() - t0) / n_iter * 1e6


def _time_jax(fn, n_iter: int = 50) -> float:
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n_iter):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n_iter * 1e6


def run() -> list[tuple]:
    rows = []
    cfg = GW_MODELS["gw_small"]
    t_len = cfg.timesteps
    params = init_autoencoder(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1, t_len, 1)).astype(np.float32)

    print(f"\n== step path: streamed B=1 vs batch (gw_small, T={t_len}, "
          f"chunk={CHUNK}) ==")

    # -- kernel-level T=1 latency: step vs wavefront -------------------------
    from repro.core.autoencoder import encoder_layers
    from repro.kernels.lstm_stack.ops import lstm_stack_op, pack_stack_cached
    from repro.kernels.lstm_stack.step import lstm_stack_step_op

    enc_p, enc_cfgs = encoder_layers(params, cfg)
    ps = pack_stack_cached(enc_p, enc_cfgs)
    x1 = ps.pad_input(jnp.asarray(w1[:, :1]))
    h0, c0 = ps.zero_state(1)
    kw = dict(acts=ps.acts, weight_dtype=ps.weight_dtype)
    us_step_k = _time_jax(
        lambda: lstm_stack_step_op(x1, ps.stacked, h0, c0, **kw)
    )
    us_big_k = _time_jax(
        lambda: lstm_stack_op(x1, ps.stacked, h0, c0, **kw)
    )
    print(f"T=1 encoder sample   : step kernel {us_step_k:7.0f} us, "
          f"wavefront {us_big_k:7.0f} us")
    rows.append(("step.kernel_t1_us", us_step_k, ""))
    rows.append(("step.kernel_fallback_t1_us", us_big_k, ""))

    # -- streamed window (fused_step engine) vs one-shot batch ---------------
    # gated row: the baseline's methodology (one full-window push per
    # score), with the window routed through the step kernel
    eng_w = StreamingAnomalyEngine(
        params, cfg, batch=1, window=t_len, chunk_len=t_len
    )
    assert eng_w.effective_impl == "fused_step", eng_w.effective_impl
    us_stream = _time(lambda: eng_w.push(w1))
    batch_eng = AnomalyStreamEngine(params, cfg)
    us_b1 = _time(lambda: batch_eng.score(w1))
    ratio = us_stream / us_b1
    print(f"streamed window, full push : {us_stream:10.0f} us")
    print(f"one-shot B=1 window        : {us_b1:10.0f} us  "
          f"(stream/batch = {ratio:.2f}x, gate <= 3.5, baseline 6.99x)")
    rows.append(("step.stream_b1_window_us", us_stream, ""))
    rows.append(("step.stream_b1_vs_batch", us_stream,
                 f"ratio={ratio:.3f}|batch_us={us_b1:.0f}|ok={int(ratio <= 3.5)}"))

    # informational: the same window streamed in 4 short chunks (default
    # chunk_len), the regime per-push glue dominates on CPU interpret
    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)

    def push_chunked():
        out = []
        for pos in range(0, t_len, CHUNK):
            out += eng.push(w1[:, pos : pos + CHUNK])
        return out[0]

    us_chunked = _time(push_chunked)
    print(f"streamed window, {t_len // CHUNK} chunks  : {us_chunked:10.0f} us "
          f"({us_chunked / us_b1:.2f}x)")
    rows.append(("step.stream_b1_chunk_us", us_chunked,
                 f"chunk={CHUNK}|ratio={us_chunked / us_b1:.3f}"))
    if ratio > 3.5:  # the PR's headline gate: the streaming gap must close
        raise RuntimeError(
            f"step.stream_b1_vs_batch ratio {ratio:.2f} > 3.5 — the "
            "low-latency step path regressed"
        )

    # -- multi-stream coalescing: 8 streams, one call per chunk --------------
    n_streams = 8
    w8 = rng.standard_normal((n_streams, t_len, 1)).astype(np.float32)
    ids = [f"s{i}" for i in range(n_streams)]
    pool = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)

    def push_many_window():
        outs = []
        for pos in range(0, t_len, CHUNK):
            res = pool.push_many(ids, w8[:, pos : pos + CHUNK])
            outs += [res[sid] for sid in ids]
        return outs

    us_many = _time(push_many_window, n_iter=5)
    seq = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)

    def push_sequential():
        scores = []
        for i in range(n_streams):
            seq.reset()
            for pos in range(0, t_len, CHUNK):
                scores += seq.push(w8[i : i + 1, pos : pos + CHUNK])
        return scores

    us_seq = _time(push_sequential, n_iter=5)

    # bit-equality gate: the coalesced scores == the sequential scores
    pool.reset()
    seq.reset()
    coal: dict = {sid: [] for sid in ids}
    for pos in range(0, t_len, CHUNK):
        res = pool.push_many(ids, w8[:, pos : pos + CHUNK])
        for sid in ids:
            coal[sid] += res[sid]
    equal = True
    for i, sid in enumerate(ids):
        seq.reset()
        want = []
        for pos in range(0, t_len, CHUNK):
            want += seq.push(w8[i : i + 1, pos : pos + CHUNK])
        equal &= len(coal[sid]) == len(want) and all(
            (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(coal[sid], want)
        )
    speedup = us_seq / us_many
    print(f"push_many x8 window : {us_many:10.0f} us vs sequential "
          f"{us_seq:10.0f} us ({speedup:.2f}x, bit-equal="
          f"{'OK' if equal else 'FAIL'})")
    rows.append(("step.push_many8_us", us_many,
                 f"sequential_us={us_seq:.0f}|speedup={speedup:.2f}|"
                 f"equal={int(equal)}"))
    rows.append(("step.push_many8_vs_sequential", 0.0,
                 f"equal={int(equal)}|speedup={speedup:.2f}"))
    if not equal:  # hard gate: coalescing must be numerically free
        raise RuntimeError(
            "push_many over 8 streams diverged from sequential pushes — "
            "the coalescer is no longer bit-exact"
        )
    return rows


if __name__ == "__main__":
    run()
