"""Continuous-batching stream server rows: fleet throughput, tail latency,
and the bit-equality hard gate.

The PR 6 serving claims, as ``server.*`` / ``serve.*`` rows merged into
the shared ``BENCH_kernels.json`` artifact (``make bench-server``):

* ``server.throughput_{1,8,32,64}streams`` — us per chunk when N
  independent B=1 streams are driven through the ``StreamServer``'s
  arrival queue + deadline coalescer (submit round-robin, drain), vs the
  same chunks pushed sequentially one stream at a time.  The 64-stream
  row is **hard-gated at >= 3x** chunks/sec over sequential — the whole
  point of the coalescer is that fleet throughput scales with batch
  width, not stream count.  The 1-stream row is **hard-gated at >=
  0.9x**: it shipped at 0.42x in PR 6 (seven pad streams created and
  dropped per tick) and must never regress below near-parity again.
* ``server.p50_us`` / ``server.p99_us`` — per-chunk enqueue->score
  latency under the saturated 64-stream load, straight from the server's
  first-class ``LatencyHistogram``.
* ``serve.p50_us`` / ``serve.p99_us`` — the single-stream per-push
  latency summary (the serve CLI's measure), through the same shared
  histogram helper (``benchmarks/latency.py``).
* ``server.adaptive_p99_vs_fixed`` — **hard gate**: a paced half-wave
  driver (32 joined streams, alternating halves of 16 submitting) runs
  once under a fixed 5 ms deadline and once under the adaptive policy.
  The idle half keeps the all-joined fast path disarmed, so the policy's
  deadline choice — not the drain path — decides when each wave fires;
  adaptive p99 must be <= fixed p99 at the same offered load (ratio
  gated <= 1.0), with both throughputs recorded alongside.
* ``server.vs_sequential_bitequal`` — **hard gate**: a scripted schedule
  with staggered joins, ragged batch fills, a mid-window
  ``close_stream`` and a rejoin scores bit-equal to per-stream
  sequential replays at ``max_coalesce=8`` (the sublane pool regime the
  step coalescer guarantees) — run under *both* the fixed policy
  (forced ragged ticks, fills 6/8/2/1) and the adaptive policy
  (non-forced ticks: the fast path, predicted-fill deadlines and width
  self-tuning pick their own groupings, which must not matter).
* ``server.flush_mix`` — scheduler instrumentation from a threaded
  deadline-paced run: tick count with full / deadline / fastpath /
  drain flush split (informational; values are host-timing dependent).
* ``server.sanitize_overhead`` — **hard gate** (PR 8): the per-chunk
  NaN/Inf/saturation screen on the submit path must cost <= 5% of a
  warm engine step for the same chunk.
* ``server.restore_bitequal`` — **hard gate** (PR 8): a server
  checkpointed mid-run (partial windows resident) and restarted via
  ``StreamServer.restart_from`` scores the remaining chunks bit-equal
  to the uninterrupted run, and the merged lineage equals sequential
  per-stream replays.

Interpret-mode timings on CPU are correctness-grade; on a TPU host the
same rows time the compiled kernels.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.latency import latency_rows, record_latencies
from repro.configs.gw import GW_MODELS
from repro.core.autoencoder import init_autoencoder
from repro.kernels.lstm_scan.ops import SUBLANES
from repro.serve.engine import StreamingAnomalyEngine
from repro.serve.health import screen_chunk
from repro.serve.server import (
    AdaptiveConfig,
    ServerConfig,
    ServerStats,
    StreamServer,
)

#: streamed chunk length (matches step_bench): 4 chunks fill a gw_small
#: window and every push rides the step kernel
CHUNK = 25

#: fleet sizes for the throughput sweep; the last one carries the gate
STREAM_COUNTS = (1, 8, 32, 64)

#: hard gate: server throughput at 64 streams must be >= this multiple
#: of sequential B=1 pushes
GATE_SPEEDUP = 3.0

#: hard gate: a single stream through the server must stay within 10% of
#: sequential pushes (the PR 6 regression shipped at 0.42x, ungated)
GATE_1STREAM = 0.9

#: hard gate: adaptive p99 / fixed p99 at equal offered load
GATE_P99_RATIO = 1.0

#: hard gate: per-chunk NaN/Inf/saturation screening must cost <= this
#: fraction of a warm engine step for the same chunk (PR 8: sanitization
#: rides the submit path, so it must be noise next to the step itself)
GATE_SANITIZE_FRAC = 0.05


def _time(fn, n_iter: int = 3) -> float:
    fn()  # warm up (compile)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6


def _throughput_pair(params, cfg, n_streams: int, data: np.ndarray):
    """(us/chunk server, us/chunk sequential, server) for one fleet size."""
    t_len = cfg.timesteps
    n_chunks = n_streams * (t_len // CHUNK)
    ids = [f"s{i}" for i in range(n_streams)]

    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    srv = StreamServer(
        eng,
        ServerConfig(
            max_coalesce=max(n_streams, SUBLANES), deadline_us=1e9
        ),
    )

    def server_window():
        # round-robin arrivals, then drain: every tick gathers a full
        # distinct-stream batch (the steady-state saturated regime)
        for pos in range(0, t_len, CHUNK):
            for i, sid in enumerate(ids):
                srv.submit(sid, data[i, pos : pos + CHUNK])
        srv.drain()
        return srv.pop_scores()

    server_window()  # warm up: compile every fill/pad shape once
    srv.stats = ServerStats()  # keep compile stalls out of the histogram

    def best_of(fn, n_iter: int = 5) -> float:
        # min over runs, not mean: both sides of the speedup ratio are
        # host-scheduling noisy on a shared CPU runner, and the gate
        # compares their *ratio* — best-case per side estimates the
        # code's actual cost (one noisy spike on either side flaked the
        # near-parity 1-stream gate when this was a 3-run mean)
        times = []
        for _ in range(n_iter):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times) * 1e6

    us_srv = best_of(server_window) / n_chunks

    seq = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)

    def sequential_window():
        scores = []
        for i in range(n_streams):
            seq.reset()
            for pos in range(0, t_len, CHUNK):
                scores += seq.push(data[i : i + 1, pos : pos + CHUNK])
        return scores

    sequential_window()  # warm
    us_seq = best_of(sequential_window) / n_chunks
    return us_srv, us_seq, srv


def _bitequal_run(params, cfg, adaptive: bool) -> tuple[bool, dict]:
    """Scripted joins/drops/ragged fills vs sequential replay, under the
    fixed policy (forced ragged ticks) or the adaptive policy (non-forced
    ticks: the scheduler picks its own groupings)."""
    t_len = cfg.timesteps
    rng = np.random.default_rng(2106)
    n = 10
    data = rng.standard_normal((n, t_len, 1)).astype(np.float32)
    rejoin = rng.standard_normal((t_len, 1)).astype(np.float32)
    ids = [f"s{i}" for i in range(n)]

    def chunk(i, k):
        return data[i, k * CHUNK : (k + 1) * CHUNK]

    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    if adaptive:
        srv = StreamServer(
            eng,
            ServerConfig(
                max_coalesce=SUBLANES,
                deadline_us=1e9,
                adaptive=AdaptiveConfig(max_deadline_us=200.0),
            ),
        )

        def settle(drain=False):
            # the real policy decides: fast path when every joined
            # stream is pending, predicted-fill deadline otherwise (the
            # 200us cap bounds the spin)
            while srv.pending:
                srv.tick()

    else:
        srv = StreamServer(
            eng, ServerConfig(max_coalesce=SUBLANES, deadline_us=1e9)
        )

        def settle(drain=False):
            if drain:
                srv.drain()
            else:
                srv.tick(force=True)

    # round 0: six early joiners -> one ragged flush at fill 6
    for i in range(6):
        srv.submit(ids[i], chunk(i, 0))
    settle()
    # round 1: four late joiners; 10 pending > max_coalesce=8 -> one full
    # flush (fill 8) + one ragged flush (fill 2)
    for i in range(n):
        srv.submit(ids[i], chunk(i, 1 if i < 6 else 0))
    settle(drain=True)
    # mid-window drop + rejoin: s3 is 50/100 samples into its window;
    # its recycled slot must not leak stale (h, c) into the fresh window
    srv.close_stream(ids[3])
    for k in (2, 3):
        for i in range(n):
            if i == 3:
                continue
            srv.submit(ids[i], chunk(i, k if i < 6 else k - 1))
        settle()  # fixed: fill 9 pending -> full 8 + 1 leftover
    for pos in range(0, t_len, CHUNK):
        srv.submit(ids[3], rejoin[pos : pos + CHUNK])
    for i in range(6, n):  # late joiners' final chunk
        srv.submit(ids[i], chunk(i, 3))
    settle(drain=True)
    srv.drain()  # fixed: any leftover; adaptive: no-op (settled)

    got = srv.pop_scores()
    seq = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    equal = True
    for i in range(n):
        seq.reset()
        want = []
        if i == 3:  # pre-drop chunks never completed a window
            for pos in range(0, t_len, CHUNK):
                want += seq.push(rejoin[None, pos : pos + CHUNK])
        else:
            for k in range(4):
                want += seq.push(chunk(i, k)[None])
        have = got.get(ids[i], [])
        equal &= len(have) == len(want) and all(
            (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(have, want)
        )
    return equal, dict(sorted(srv.stats.batch_fill.items()))


def _bitequal_gate(params, cfg) -> tuple:
    """Bit-equality hard gate, fixed and adaptive scheduling."""
    eq_fixed, fills_fixed = _bitequal_run(params, cfg, adaptive=False)
    eq_adaptive, fills_adaptive = _bitequal_run(params, cfg, adaptive=True)
    ok = eq_fixed and eq_adaptive
    print(f"bit-equality gate    : {'OK' if ok else 'FAIL'} "
          f"(10 streams, drop+rejoin; fixed fills {fills_fixed}, "
          f"adaptive fills {fills_adaptive})")
    row = ("server.vs_sequential_bitequal", 0.0,
           f"equal_fixed={int(eq_fixed)}|equal_adaptive={int(eq_adaptive)}|"
           f"streams=10|fills_fixed={'/'.join(map(str, fills_fixed))}|"
           f"fills_adaptive={'/'.join(map(str, fills_adaptive))}|"
           f"ok={int(ok)}")
    if not ok:  # hard gate: the scheduler must be numerically free
        raise RuntimeError(
            "StreamServer scores diverged from sequential per-stream "
            "pushes under joins/drops/ragged fills "
            f"(fixed equal={eq_fixed}, adaptive equal={eq_adaptive}) — "
            "the continuous-batching scheduler is no longer bit-exact"
        )
    return row


def _paced_run(params, cfg, server_config) -> tuple[ServerStats, float]:
    """Half-wave paced driver: 32 joined streams, alternating halves of
    16 submit one chunk each, then the driver ticks until the wave is
    scored.  The idle half keeps the all-joined fast path disarmed, so
    the policy's deadline choice — not the drain path — decides when
    each wave fires.  Returns (stats, chunks/sec)."""
    t_len = cfg.timesteps
    n = 32
    half = n // 2
    rng = np.random.default_rng(42)
    data = rng.standard_normal((n, t_len, 1)).astype(np.float32)
    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    srv = StreamServer(eng, server_config)

    def wave(pos, lo, forced):
        for i in range(lo, lo + half):
            srv.submit(f"s{i}", data[i, pos : pos + CHUNK])
        if forced:
            srv.tick(force=True)
        else:
            while srv.pending:  # policy decides when the wave fires
                srv.tick()

    # warm one full window of half-waves first (fill-16 chunk pushes AND
    # the fill-16 window-completion shape), so compile stalls stay out of
    # both histograms; the warm-up ends on a window boundary, so the
    # measured pass replays the identical window phase
    for pos in range(0, t_len, CHUNK):
        for lo in (0, half):
            wave(pos, lo, forced=True)
    srv.pop_scores()
    srv.stats = ServerStats()

    n_chunks = 0
    t0 = time.perf_counter()
    for pos in range(0, t_len, CHUNK):
        for lo in (0, half):
            wave(pos, lo, forced=False)
            n_chunks += half
    wall_s = time.perf_counter() - t0
    srv.pop_scores()
    return srv.stats, n_chunks / wall_s


def _adaptive_vs_fixed_row(params, cfg) -> tuple:
    """Adaptive-vs-fixed p99 at equal offered load (hard gate <= 1.0)."""
    fixed_stats, fixed_tput = _paced_run(
        params, cfg,
        ServerConfig(max_coalesce=32, deadline_us=5000.0),
    )
    adapt_stats, adapt_tput = _paced_run(
        params, cfg,
        ServerConfig(
            max_coalesce=32,
            deadline_us=5000.0,  # ignored: adaptive picks the deadline
            adaptive=AdaptiveConfig(max_deadline_us=500.0),
        ),
    )
    fixed_p99 = fixed_stats.latency.percentile(99)
    adapt_p99 = adapt_stats.latency.percentile(99)
    ratio = adapt_p99 / fixed_p99 if fixed_p99 > 0 else float("inf")
    ok = ratio <= GATE_P99_RATIO
    print(f"adaptive vs fixed    : p99 {adapt_p99:7.0f} us vs "
          f"{fixed_p99:7.0f} us ({ratio:.2f}x, gate <= 1.0); "
          f"{adapt_tput:.0f} vs {fixed_tput:.0f} chunks/s")
    row = ("server.adaptive_p99_vs_fixed", adapt_p99,
           f"ratio={ratio:.3f}|fixed_p99_us={fixed_p99:.0f}|"
           f"adaptive_chunks_per_s={adapt_tput:.0f}|"
           f"fixed_chunks_per_s={fixed_tput:.0f}|ok={int(ok)}")
    if not ok:
        raise RuntimeError(
            f"adaptive p99 {adapt_p99:.0f}us > fixed p99 {fixed_p99:.0f}us "
            f"at equal offered load (ratio {ratio:.2f} > "
            f"{GATE_P99_RATIO:.1f}) — the adaptive policy must dominate "
            "the fixed deadline it replaces"
        )
    return row


def _flush_mix_row(params, cfg) -> tuple:
    """Threaded deadline-paced mini-run for the flush-mix instrumentation."""
    t_len = cfg.timesteps
    rng = np.random.default_rng(7)
    n = 16
    data = rng.standard_normal((n, t_len, 1)).astype(np.float32)
    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    srv = StreamServer(
        eng,
        ServerConfig(max_coalesce=SUBLANES, deadline_us=2000.0),
    )
    with srv:
        for pos in range(0, t_len, CHUNK):
            for i in range(n):
                srv.submit(f"s{i}", data[i, pos : pos + CHUNK])
    st = srv.stats
    print(f"flush mix (16 streams, 2ms deadline): {st.ticks} ticks — "
          f"{st.full_flushes} full, {st.deadline_flushes} deadline, "
          f"{st.fastpath_flushes} fastpath, {st.drain_flushes} drain")
    return ("server.flush_mix", float(st.ticks),
            f"full={st.full_flushes}|deadline={st.deadline_flushes}|"
            f"fastpath={st.fastpath_flushes}|drain={st.drain_flushes}|"
            f"drops={st.drops}")


def _sanitize_overhead_row(params, cfg) -> tuple:
    """Screening cost per chunk vs a warm engine step for the same chunk
    (hard gate: <= ``GATE_SANITIZE_FRAC`` of step time).  The screen is
    one ``max(|x|)`` pass on the host; the step is the warm single-stream
    ``push`` the screen fronts on the submit path."""
    t_len = cfg.timesteps
    rng = np.random.default_rng(8)
    chunk = rng.standard_normal((CHUNK, 1)).astype(np.float32)
    n_iter = 2000
    screen_chunk(chunk, 1e6)  # warm
    t0 = time.perf_counter()
    for _ in range(n_iter):
        screen_chunk(chunk, 1e6)
    screen_us = (time.perf_counter() - t0) / n_iter * 1e6

    eng = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    data = rng.standard_normal((1, t_len, 1)).astype(np.float32)

    def window():
        for pos in range(0, t_len, CHUNK):
            eng.push(data[:, pos : pos + CHUNK])

    step_us = _time(window, n_iter=5) / (t_len // CHUNK)
    frac = screen_us / step_us
    ok = frac <= GATE_SANITIZE_FRAC
    print(f"sanitize overhead    : {screen_us:7.2f} us/chunk screen vs "
          f"{step_us:7.0f} us/chunk step ({frac * 100:.2f}%, gate <= "
          f"{GATE_SANITIZE_FRAC * 100:.0f}%)")
    row = ("server.sanitize_overhead", screen_us,
           f"step_us={step_us:.1f}|fraction={frac:.4f}|"
           f"chunk_t={CHUNK}|ok={int(ok)}")
    if not ok:
        raise RuntimeError(
            f"chunk screening costs {screen_us:.2f} us = {frac * 100:.1f}% "
            f"of a {step_us:.0f} us step (gate <= "
            f"{GATE_SANITIZE_FRAC * 100:.0f}%) — sanitization must stay "
            "noise next to the step it protects"
        )
    return row


def _restore_bitequal_row(params, cfg) -> tuple:
    """Snapshot -> restart -> resume equals the uninterrupted run, bit for
    bit (hard gate).  Mid-run checkpoint with partial windows resident,
    restored into a *fresh* engine + server; both lineages then score the
    identical tail and must agree exactly, and the merged run must equal
    sequential per-stream replays."""
    t_len = cfg.timesteps
    rng = np.random.default_rng(2207)
    n, n_chunks = 4, 6  # 25-sample chunks on a 100 window: chunk 2 is
    ids = [f"s{i}" for i in range(n)]  # mid-window at the checkpoint
    data = rng.standard_normal((n, n_chunks * CHUNK, 1)).astype(np.float32)

    def chunk(i, k):
        return data[i, k * CHUNK : (k + 1) * CHUNK]

    def drive(srv, lo, hi):
        for k in range(lo, hi):
            for i, sid in enumerate(ids):
                srv.submit(sid, np.array(chunk(i, k)))
            srv.drain()
        return srv.pop_scores()

    cut = 3  # 75 of 100 samples: every stream checkpoints mid-window
    srv = StreamServer(
        StreamingAnomalyEngine(params, cfg, batch=1, window=t_len),
        ServerConfig(health=True),
    )
    head = drive(srv, 0, cut)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "server.ckpt.npz")
        srv.checkpoint(path)
        restarted = StreamServer.restart_from(
            path,
            StreamingAnomalyEngine(params, cfg, batch=1, window=t_len),
            ServerConfig(health=True),
        )
        tail_a = drive(srv, cut, n_chunks)
        tail_b = drive(restarted, cut, n_chunks)

    seq = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    equal = True
    for i, sid in enumerate(ids):
        seq.reset()
        want = []
        for k in range(n_chunks):
            want += seq.push(chunk(i, k)[None])
        merged = head.get(sid, []) + tail_a.get(sid, [])
        resumed = tail_b.get(sid, [])
        equal &= len(tail_a.get(sid, [])) == len(resumed) and all(
            (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(tail_a.get(sid, []), resumed)
        )
        equal &= len(merged) == len(want) and all(
            (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(merged, want)
        )
    print(f"restore bit-equality : {'OK' if equal else 'FAIL'} "
          f"({n} streams checkpointed mid-window, resumed vs uninterrupted)")
    row = ("server.restore_bitequal", 0.0,
           f"equal={int(equal)}|streams={n}|checkpoint_chunk={cut}|"
           f"ok={int(equal)}")
    if not equal:
        raise RuntimeError(
            "a server restarted from a mid-run snapshot did not score "
            "bit-equal to the uninterrupted run — snapshot/restore is "
            "dropping or corrupting stream state"
        )
    return row


def run() -> list[tuple]:
    rows = []
    cfg = GW_MODELS["gw_small"]
    t_len = cfg.timesteps
    params = init_autoencoder(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    data = rng.standard_normal(
        (max(STREAM_COUNTS), t_len, 1)
    ).astype(np.float32)

    print(f"\n== stream server: continuous batching (gw_small, T={t_len}, "
          f"chunk={CHUNK}) ==")

    # -- single-stream per-push latency (the serve CLI's measure) ------------
    solo = StreamingAnomalyEngine(params, cfg, batch=1, window=t_len)
    for pos in range(0, t_len, CHUNK):  # warm up the chunked push path
        solo.push(data[:1, pos : pos + CHUNK])
    samples = []
    for _ in range(5):
        for pos in range(0, t_len, CHUNK):
            t0 = time.perf_counter()
            solo.push(data[:1, pos : pos + CHUNK])
            samples.append((time.perf_counter() - t0) * 1e6)
    hist = record_latencies(samples)
    rows += latency_rows("serve", hist)
    print(f"single-stream push   : p50 {hist.percentile(50):7.0f} us, "
          f"p99 {hist.percentile(99):7.0f} us")

    # -- throughput sweep + 1-stream and 64-stream gates ---------------------
    gate_speedup = None
    gate_1stream = None
    srv64 = None
    for n_streams in STREAM_COUNTS:
        us_srv, us_seq, srv = _throughput_pair(
            params, cfg, n_streams, data[:n_streams]
        )
        speedup = us_seq / us_srv
        gated = n_streams in (1, max(STREAM_COUNTS))
        derived = (
            f"chunks_per_s={1e6 / us_srv:.0f}|sequential_us={us_seq:.0f}|"
            f"speedup={speedup:.2f}"
        )
        if n_streams == 1:
            derived += f"|ok={int(speedup >= GATE_1STREAM)}"
            gate_1stream = speedup
        elif gated:
            derived += f"|ok={int(speedup >= GATE_SPEEDUP)}"
            gate_speedup = speedup
            srv64 = srv
        rows.append((f"server.throughput_{n_streams}streams", us_srv, derived))
        gate_note = (
            ", gate >= 0.9" if n_streams == 1
            else ", gate >= 3.0" if gated else ""
        )
        print(f"{n_streams:3d} streams          : {us_srv:7.0f} us/chunk "
              f"server vs {us_seq:7.0f} sequential ({speedup:.2f}x"
              f"{gate_note})")

    # tail latency under the saturated 64-stream load (drain-mode: chunks
    # queue a full round-robin wave, so the histogram is queue-dominated)
    rows += latency_rows("server", srv64.stats.latency)
    print(f"64-stream load       : p50 {srv64.stats.latency.percentile(50):7.0f} us, "
          f"p99 {srv64.stats.latency.percentile(99):7.0f} us enqueue->score")

    rows.append(_adaptive_vs_fixed_row(params, cfg))
    rows.append(_bitequal_gate(params, cfg))
    rows.append(_flush_mix_row(params, cfg))
    rows.append(_sanitize_overhead_row(params, cfg))
    rows.append(_restore_bitequal_row(params, cfg))

    if gate_1stream < GATE_1STREAM:  # the 0.42x regression, now gated
        raise RuntimeError(
            f"server.throughput_1streams speedup {gate_1stream:.2f}x < "
            f"{GATE_1STREAM:.1f}x sequential — a lone stream through the "
            "server must stay near parity (fast path + width-1 pad rung)"
        )
    if gate_speedup < GATE_SPEEDUP:  # the PR 6 headline gate
        raise RuntimeError(
            f"server.throughput_64streams speedup {gate_speedup:.2f}x < "
            f"{GATE_SPEEDUP:.1f}x over sequential pushes — continuous "
            "batching is no longer paying for itself"
        )
    return rows


if __name__ == "__main__":
    run()
