"""Kernel micro-benchmarks (interpret on CPU: correctness-grade timing only)
+ the analytic VMEM/HBM traffic comparison that motivates the fused scan
and the fused multi-layer stack.

The fused lstm_scan keeps (h, c) and W_h in VMEM for the whole sequence:
HBM traffic per step drops from (read xW, read W_h, read h, write h, write
gates) to (read xW block, write h block).  The fused *stack* goes further:
per-layer kernels still round-trip every layer's (T, B, H) hidden sequence
through HBM between layers; the wavefront stack hands h layer-to-layer in
VMEM, so only layer 0's xW streams in and the last layer's hs streams out.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.autoencoder import (
    AutoencoderConfig,
    autoencoder_forward,
    init_autoencoder,
)
from repro.core.lstm import LstmConfig, init_lstm, lstm_forward


def traffic_model(batch: int, t: int, lx: int, lh: int) -> dict:
    """HBM bytes per full sequence, naive scan vs fused kernel (bf16=2B)."""
    e = 2
    xw = t * batch * 4 * lh * 4        # fp32 gate stream
    w_h = lh * 4 * lh * e
    h_io = t * batch * lh * e
    naive = xw + t * (w_h + 2 * batch * lh * e) + h_io  # W_h + h/c per step
    fused = xw + w_h + h_io                              # once, once, once
    return {"naive": naive, "fused": fused, "saving": 1 - fused / naive}


def stack_traffic_model(batch: int, t: int, n_layers: int, w: int) -> dict:
    """HBM bytes per sequence for an L-layer packed stack (width W, bf16=2B):
    per-layer fused kernels vs the single wavefront kernel."""
    e = 2
    weights = n_layers * 2 * w * 4 * w * e       # W_x + W_h, read once either way
    xw0 = t * batch * 4 * w * 4                  # layer-0 fp32 gate stream
    hs_out = t * batch * w * e                   # last layer's hidden sequence
    inter = (n_layers - 1) * 2 * t * batch * w * e  # h write + read per boundary
    # per-layer also materializes every inner layer's (T, B, 4W) fp32 gate
    # stream in HBM (XLA matmul writes it, the next pallas_call reads it);
    # the fused kernel computes those projections in-kernel from VMEM
    inter_xw = (n_layers - 1) * 2 * t * batch * 4 * w * 4
    per_layer = weights + xw0 + hs_out + inter + inter_xw
    fused = weights + xw0 + hs_out
    return {
        "per_layer": per_layer,
        "fused": fused,
        "saving": 1 - fused / per_layer,
    }


def run() -> list[tuple]:
    rows = []
    print("\n== kernels: fused LSTM scan HBM-traffic model (per sequence) ==")
    for b, t, lx, lh in [(1, 100, 1, 32), (128, 100, 1, 32), (256, 1024, 64, 256)]:
        m = traffic_model(b, t, lx, lh)
        print(f"B={b:<4} T={t:<5} H={lh:<4}: naive={m['naive']/1e6:8.2f}MB "
              f"fused={m['fused']/1e6:8.2f}MB  saving={m['saving']:.1%}")
        rows.append((f"kernel.traffic.b{b}t{t}h{lh}", 0.0,
                     f"saving={m['saving']:.3f}"))

    print("\n== kernels: fused STACK HBM-traffic model (per sequence) ==")
    for b, t, l, w in [(1, 100, 4, 32), (256, 100, 4, 32), (256, 100, 2, 128)]:
        m = stack_traffic_model(b, t, l, w)
        print(f"B={b:<4} T={t:<4} L={l} W={w:<4}: "
              f"per-layer={m['per_layer']/1e6:8.2f}MB "
              f"fused={m['fused']/1e6:8.2f}MB  saving={m['saving']:.1%}")
        rows.append((f"kernel.stack_traffic.b{b}l{l}w{w}", 0.0,
                     f"saving={m['saving']:.3f}"))

    # wall-clock of the three execution paths on this host (small model)
    cfg = LstmConfig(in_dim=8, hidden=32)
    params = init_lstm(jax.random.PRNGKey(0), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (16, 100, 8))
    for impl in ("naive", "split"):
        f = jax.jit(lambda p, x, impl=impl: lstm_forward(p, x, cfg, impl=impl)[0])
        jax.block_until_ready(f(params, xs))
        t0 = time.perf_counter()
        for _ in range(30):
            out = f(params, xs)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 30 * 1e6
        print(f"lstm_forward[{impl:>6}] (B16,T100,H32) host: {us:8.1f} us")
        rows.append((f"kernel.lstm_{impl}_us", us, ""))

    # ---- the nominal GW autoencoder, all four backends -------------------
    # naive/split are pure-XLA scans; kernel = per-layer Pallas scans (each
    # layer's hidden sequence round-trips HBM); fused_stack = one wavefront
    # kernel per segment.  Acceptance: fused_stack strictly below kernel.
    print("\n== kernels: GW nominal autoencoder (32,8,8,32) B=256 T=100 ==")
    ae_cfg = AutoencoderConfig(hidden=(32, 8, 8, 32), timesteps=100)
    ae_params = init_autoencoder(jax.random.PRNGKey(2), ae_cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 100, 1))
    ae_us = {}
    for impl in ("naive", "split", "kernel", "fused_stack"):
        c = dataclasses.replace(ae_cfg, impl=impl)
        f = jax.jit(lambda p, x, c=c: autoencoder_forward(p, x, c))
        jax.block_until_ready(f(ae_params, x))
        n_iter = 5
        t0 = time.perf_counter()
        for _ in range(n_iter):
            out = f(ae_params, x)
        jax.block_until_ready(out)
        ae_us[impl] = us = (time.perf_counter() - t0) / n_iter * 1e6
        print(f"gw_nominal_ae[{impl:>11}] (B256,T100): {us:10.0f} us")
        rows.append((f"kernel.gw_ae_{impl}_us", us, ""))
    speedup = ae_us["kernel"] / ae_us["fused_stack"]
    ok = ae_us["fused_stack"] < ae_us["kernel"]
    print(f"fused-stack vs per-layer-kernel: {speedup:.2f}x "
          f"({'OK' if ok else 'REGRESSION'})")
    rows.append(("kernel.gw_ae_fused_vs_perlayer", 0.0,
                 f"speedup={speedup:.2f}|ok={int(ok)}"))
    return rows


if __name__ == "__main__":
    run()
