"""Kernel micro-benchmarks (interpret on CPU: correctness-grade timing only)
+ the analytic VMEM/HBM traffic comparison that motivates the fused scan.

The fused lstm_scan keeps (h, c) and W_h in VMEM for the whole sequence:
HBM traffic per step drops from (read xW, read W_h, read h, write h, write
gates) to (read xW block, write h block) — the table quantifies it.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.lstm import LstmConfig, init_lstm, lstm_forward


def traffic_model(batch: int, t: int, lx: int, lh: int) -> dict:
    """HBM bytes per full sequence, naive scan vs fused kernel (bf16=2B)."""
    e = 2
    xw = t * batch * 4 * lh * 4        # fp32 gate stream
    w_h = lh * 4 * lh * e
    h_io = t * batch * lh * e
    naive = xw + t * (w_h + 2 * batch * lh * e) + h_io  # W_h + h/c per step
    fused = xw + w_h + h_io                              # once, once, once
    return {"naive": naive, "fused": fused, "saving": 1 - fused / naive}


def run() -> list[tuple]:
    rows = []
    print("\n== kernels: fused LSTM scan HBM-traffic model (per sequence) ==")
    for b, t, lx, lh in [(1, 100, 1, 32), (128, 100, 1, 32), (256, 1024, 64, 256)]:
        m = traffic_model(b, t, lx, lh)
        print(f"B={b:<4} T={t:<5} H={lh:<4}: naive={m['naive']/1e6:8.2f}MB "
              f"fused={m['fused']/1e6:8.2f}MB  saving={m['saving']:.1%}")
        rows.append((f"kernel.traffic.b{b}t{t}h{lh}", 0.0,
                     f"saving={m['saving']:.3f}"))

    # wall-clock of the three execution paths on this host (small model)
    cfg = LstmConfig(in_dim=8, hidden=32)
    params = init_lstm(jax.random.PRNGKey(0), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (16, 100, 8))
    for impl in ("naive", "split"):
        f = jax.jit(lambda p, x, impl=impl: lstm_forward(p, x, cfg, impl=impl)[0])
        jax.block_until_ready(f(params, xs))
        t0 = time.perf_counter()
        for _ in range(30):
            out = f(params, xs)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 30 * 1e6
        print(f"lstm_forward[{impl:>6}] (B16,T100,H32) host: {us:8.1f} us")
        rows.append((f"kernel.lstm_{impl}_us", us, ""))
    return rows


if __name__ == "__main__":
    run()
