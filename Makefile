# Tier-1 verification + benchmark entry points.  Everything runs on CPU
# (Pallas kernels in interpret mode); on a TPU host the same commands use
# the compiled kernels automatically.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-serve test-quant test-exec test-step test-server test-chaos test-autotune test-mixed tune bench-kernels bench-stream bench-quant bench-exec bench-step bench-server bench-autotune bench-mixed bench

test:
	$(PYTHON) -m pytest -x -q

# skip the slow end-to-end training test
test-fast:
	$(PYTHON) -m pytest -x -q --deselect tests/test_gw_e2e.py

# the stateful streaming serving path (equivalence, cache, donation)
test-serve:
	$(PYTHON) -m pytest -x -q tests/test_serve_streaming.py

# the quantized packed-weight fused stack (grid, kernel, cache, serving)
test-quant:
	$(PYTHON) -m pytest -x -q tests/test_quant_stack.py

# the plan/bind/execute API (plans, executors, sharded fused wavefront)
test-exec:
	$(PYTHON) -m pytest -x -q tests/test_executor.py

# the low-latency step kernel + multi-stream coalescing (bitwise contract)
test-step:
	$(PYTHON) -m pytest -x -q tests/test_step_kernel.py

# the continuous-batching stream server (deadline coalescer, backpressure,
# scheduler determinism, latency histogram)
test-server:
	$(PYTHON) -m pytest -x -q tests/test_stream_server.py

# the fault-injection suite (glitch quarantine, engine faults + watchdog,
# snapshot/restore, scheduler supervision, close-vs-batch race)
test-chaos:
	$(PYTHON) -m pytest -x -q tests/test_chaos.py

# the autotune subsystem (knob spaces, tuned-plan cache, cached planning,
# sweep harness, roofline model, HLO custom-call costs)
test-autotune:
	$(PYTHON) -m pytest -x -q tests/test_autotune.py

# the heterogeneous mixed backend (per-layer storage splits, segment
# chaining bit-equality, balancer, act_bits, tuned split, mixed serving)
test-mixed:
	$(PYTHON) -m pytest -x -q tests/test_mixed_stack.py

# measure the standard smoke grid on THIS machine and populate the
# tuned-plan cache (runs/autotune/tuned.json) that `--tune cached` serving
# reads; run on the hardware you serve on
tune:
	$(PYTHON) -m repro.launch.tune --smoke

# kernel + pipeline + streaming-serve rows, with the machine-readable artifact
bench-kernels:
	$(PYTHON) -m benchmarks.run --only kernels_bench,pipeline_balance,stream --json BENCH_kernels.json

# fast path: just the streaming B=1 vs batch serving rows
bench-stream:
	$(PYTHON) -m benchmarks.run --only stream --json BENCH_stream.json

# quant.* rows (packed bytes ratio, fused latency, AUC parity, serving gate)
# merged into the shared artifact next to the kernel rows
bench-quant:
	$(PYTHON) -m benchmarks.run --only quant --json BENCH_kernels.json --merge

# exec.* rows (dispatch overhead, pack gate, sharded wavefront) merged
# into the shared artifact next to the kernel + quant rows
bench-exec:
	$(PYTHON) -m benchmarks.run --only exec --json BENCH_kernels.json --merge

# step.* rows (streamed-vs-batch gap gate, T=1 kernel latency, coalescing
# bit-equality gate) merged into the shared artifact
bench-step:
	$(PYTHON) -m benchmarks.run --only step --json BENCH_kernels.json --merge

# server.* / serve.* rows (fleet throughput gate >= 3x at 64 streams,
# p50/p99 under load, scheduler bit-equality gate) merged into the artifact
bench-server:
	$(PYTHON) -m benchmarks.run --only server --json BENCH_kernels.json --merge

# autotune.* rows (smoke sweep best-vs-default hard gate >= 1.0x, roofline
# model-gated predicted-vs-measured rows) merged into the artifact.  CI
# runs this BEFORE bench-kernels (which rewrites BENCH_kernels.json), so
# it redirects to its own artifact with AUTOTUNE_JSON=BENCH_autotune.json.
AUTOTUNE_JSON ?= BENCH_kernels.json
bench-autotune:
	$(PYTHON) -m benchmarks.run --only autotune --json $(AUTOTUNE_JSON) --merge

# mixed.* rows (chained bit-equality hard gate, measured-best split vs
# best homogeneous hard gate >= 1.0x, fitted-balancer gate=model row)
# merged into the shared artifact
bench-mixed:
	$(PYTHON) -m benchmarks.run --only mixed --json BENCH_kernels.json --merge

bench:
	$(PYTHON) -m benchmarks.run --fast --json BENCH_kernels.json
