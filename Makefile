# Tier-1 verification + benchmark entry points.  Everything runs on CPU
# (Pallas kernels in interpret mode); on a TPU host the same commands use
# the compiled kernels automatically.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-kernels bench

test:
	$(PYTHON) -m pytest -x -q

# skip the slow end-to-end training test
test-fast:
	$(PYTHON) -m pytest -x -q --deselect tests/test_gw_e2e.py

# kernel + pipeline rows only, with the machine-readable perf artifact
bench-kernels:
	$(PYTHON) -m benchmarks.run --only kernels_bench,pipeline_balance --json BENCH_kernels.json

bench:
	$(PYTHON) -m benchmarks.run --fast --json BENCH_kernels.json
