"""End-to-end driver: train the NOMINAL GW autoencoder (paper Sec. V) with
the full substrate — data pipeline, AdamW, checkpoint/restart, straggler
monitor — then evaluate AUC and the 16-bit quantization parity claim.

Run:  PYTHONPATH=src python examples/train_gw_autoencoder.py [--steps 300]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autoencoder import (
    AutoencoderConfig,
    init_autoencoder,
    mse_loss,
)
from repro.core.quant import quantize_tree
from repro.data.gw import GwDataConfig, GwDataset
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ckpt", default="runs/gw_nominal_ckpt")
    args = ap.parse_args()

    cfg = AutoencoderConfig(hidden=(32, 8, 8, 32), timesteps=100)
    ds = GwDataset(GwDataConfig(timesteps=100, seed=0))

    def data():
        for x in ds.train_stream(args.batch):
            yield {"x": jnp.asarray(x)}

    trainer = Trainer(
        loss_fn=lambda p, b: mse_loss(p, b["x"], cfg),
        init_params_fn=lambda rng: init_autoencoder(rng, cfg),
        data_iter=data(),
        cfg=TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=max(args.steps // 3, 1),
            opt=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                            weight_decay=0.0),
        ),
        ckpt_dir=args.ckpt,
    )
    result = trainer.run(jax.random.PRNGKey(0))
    print(f"trained to step {result.step}; loss "
          f"{result.losses[0]:.4f} -> {result.losses[-1]:.4f}; "
          f"stragglers flagged: {len(result.straggler_events)}; "
          f"resumed_from={result.resumed_from}")

    from benchmarks.fig9_auc import evaluate_auc

    auc = evaluate_auc(trainer.params, cfg, ds, n=256)
    auc_q = evaluate_auc(quantize_tree(trainer.params), cfg, ds, n=256)
    print(f"AUC fp32: {auc:.3f} | AUC 16-bit fixed: {auc_q:.3f} "
          f"(delta {auc_q - auc:+.3f}; paper: negligible)")


if __name__ == "__main__":
    main()
