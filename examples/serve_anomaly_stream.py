"""Streaming anomaly detection: the paper's deployment scenario.

Loads (or quickly trains) the small autoencoder, calibrates the anomaly
threshold at a target FPR on background, then processes a simulated strain
stream batch-1 — the latency-critical mode the paper's FPGA design targets
(Table III) — reporting per-window latency and detection counts.

Run:  PYTHONPATH=src python examples/serve_anomaly_stream.py
"""

import time

import jax
import numpy as np

from benchmarks.fig9_auc import train_autoencoder
from repro.configs.gw import GW_MODELS
from repro.data.gw import GwDataConfig, GwDataset
from repro.serve.engine import AnomalyStreamEngine


def main():
    cfg = GW_MODELS["gw_small"]
    print("training detector on background ...")
    params, _, ds = train_autoencoder(cfg, steps=150, batch=32)

    engine = AnomalyStreamEngine(params, cfg)
    thr = engine.calibrate(ds.background(512), fpr=0.01)
    print(f"calibrated threshold (1% FPR): {thr:.4f}")

    # simulated stream: mostly background, occasional injected events
    rng = np.random.default_rng(0)
    n_windows, n_events = 200, 0
    lat = []
    hits = misses = false_alarms = 0
    for i in range(n_windows):
        is_event = rng.random() < 0.1
        w = ds.events(1) if is_event else ds.background(1)
        t0 = time.perf_counter()
        flagged = bool(engine.flag(w)[0])
        lat.append(time.perf_counter() - t0)
        n_events += is_event
        hits += flagged and is_event
        misses += (not flagged) and is_event
        false_alarms += flagged and not is_event

    lat_us = np.asarray(lat[10:]) * 1e6  # drop warmup
    print(f"stream: {n_windows} windows, {n_events} events")
    print(f"detected {hits}/{n_events}; false alarms "
          f"{false_alarms}/{n_windows - n_events} "
          f"({false_alarms / max(n_windows - n_events, 1):.1%}, target 1%)")
    print(f"batch-1 scoring latency: p50={np.percentile(lat_us, 50):.0f}us "
          f"p99={np.percentile(lat_us, 99):.0f}us on this host CPU "
          f"(paper FPGA: 0.40us; TPU roofline: see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
