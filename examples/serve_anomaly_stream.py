"""Streaming anomaly detection: the paper's deployment scenario.

Loads (or quickly trains) the small autoencoder, calibrates the anomaly
threshold at a target FPR on background, then processes a simulated strain
stream batch-1 — the latency-critical mode the paper's FPGA design targets
(Table III).  Two serving paths are exercised on the same calibrated
threshold:

* one-shot window scoring (``AnomalyStreamEngine``), and
* stateful chunked streaming (``StreamingAnomalyEngine``): strain arrives
  in quarter-window chunks, encoder (h, c) stays resident between pushes
  (pre-packed weights, donated state buffers), and the two paths must
  agree on every score.

Finally one window runs through an int8 quantized streaming engine
(``weight_dtype="int8"``: packed codes VMEM-resident, scales in SMEM) and
the score delta vs fp32 is reported — the paper's 16-bit parity claim at
serving time — and four independent detectors' streams are served through
the multi-stream coalescer (``push_many``: one gathered B=4 step call per
chunk, bit-equal to solo replays).

Run:  PYTHONPATH=src:. python examples/serve_anomaly_stream.py
"""

import time

import numpy as np

from benchmarks.fig9_auc import train_autoencoder
from repro.configs.gw import GW_MODELS
from repro.serve.engine import AnomalyStreamEngine, StreamingAnomalyEngine


def main():
    cfg = GW_MODELS["gw_small"]
    print("training detector on background ...")
    params, _, ds = train_autoencoder(cfg, steps=150, batch=32)

    engine = AnomalyStreamEngine(params, cfg)
    thr = engine.calibrate(ds.background(512), fpr=0.01)
    print(f"calibrated threshold (1% FPR): {thr:.4f} "
          f"[impl={engine.effective_impl}]")

    # the streaming twin shares params, impl and threshold; strain arrives
    # in quarter-window chunks and the encoder state persists between pushes
    stream = StreamingAnomalyEngine(params, cfg, batch=1, threshold=thr)
    chunk = cfg.timesteps // 4

    # simulated stream: mostly background, occasional injected events
    rng = np.random.default_rng(0)
    n_windows, n_events = 200, 0
    lat, stream_lat = [], []
    hits = misses = false_alarms = 0
    max_disagree = 0.0
    for i in range(n_windows):
        is_event = rng.random() < 0.1
        w = ds.events(1) if is_event else ds.background(1)

        t0 = time.perf_counter()
        score = engine.score(w)[0]
        lat.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        chunk_scores = []
        for pos in range(0, cfg.timesteps, chunk):
            chunk_scores += stream.push(w[:, pos : pos + chunk])
        stream_lat.append(time.perf_counter() - t0)
        max_disagree = max(max_disagree, abs(float(chunk_scores[0][0]) - score))

        flagged = score > thr
        n_events += is_event
        hits += flagged and is_event
        misses += (not flagged) and is_event
        false_alarms += flagged and not is_event

    lat_us = np.asarray(lat[10:]) * 1e6  # drop warmup
    s_us = np.asarray(stream_lat[10:]) * 1e6
    print(f"stream: {n_windows} windows, {n_events} events")
    print(f"detected {hits}/{n_events}; false alarms "
          f"{false_alarms}/{n_windows - n_events} "
          f"({false_alarms / max(n_windows - n_events, 1):.1%}, target 1%)")
    print(f"one-shot scoring latency : p50={np.percentile(lat_us, 50):.0f}us "
          f"p99={np.percentile(lat_us, 99):.0f}us on this host CPU")
    print(f"chunked streaming latency: p50={np.percentile(s_us, 50):.0f}us "
          f"p99={np.percentile(s_us, 99):.0f}us "
          f"({cfg.timesteps // chunk} pushes/window, state resident)")
    print(f"max |streaming - one-shot| score gap: {max_disagree:.2e}")
    print("(paper FPGA: 0.40us; TPU roofline: see EXPERIMENTS.md)")

    # quantized serving for free: same params, int8 VMEM weight storage
    # (per-layer scales in SMEM, fp32 cell carry) picked up straight from
    # the config — one window through the quantized stream vs the fp32 score
    import dataclasses

    cfg_q = dataclasses.replace(cfg, weight_dtype="int8")
    stream_q = StreamingAnomalyEngine(params, cfg_q, batch=1, threshold=thr)
    w = ds.background(1)
    score_fp32 = engine.score(w)[0]
    (scores_q,) = stream_q.push(w)
    delta = abs(float(scores_q[0]) - score_fp32)
    print(f"int8 quantized push: score={float(scores_q[0]):.5f} vs "
          f"fp32={score_fp32:.5f} (|delta|={delta:.2e}, "
          f"rel={delta / max(abs(score_fp32), 1e-12):.2%})")
    assert delta <= max(abs(score_fp32) * 0.1, 1e-3), (
        "int8 quantized score drifted from fp32 beyond fixed-point tolerance"
    )

    # multi-stream coalescing: 4 independent detectors' streams advanced by
    # ONE gathered B=4 step call per chunk (push_many) — scores must be
    # bit-equal to pushing each stream through its own engine
    pool = StreamingAnomalyEngine(params, cfg, batch=1, threshold=thr)
    solo = StreamingAnomalyEngine(params, cfg, batch=1, threshold=thr)
    ids = [f"det{i}" for i in range(4)]
    w4 = np.concatenate([ds.background(1) for _ in ids])
    pooled: dict = {sid: [] for sid in ids}
    for pos in range(0, cfg.timesteps, chunk):
        res = pool.push_many(ids, w4[:, pos : pos + chunk])
        for sid in ids:
            pooled[sid] += res[sid]
    for i, sid in enumerate(ids):
        solo.reset()
        want = []
        for pos in range(0, cfg.timesteps, chunk):
            want += solo.push(w4[i : i + 1, pos : pos + chunk])
        assert (np.asarray(pooled[sid][0]) == np.asarray(want[0])).all(), (
            f"coalesced stream {sid} diverged from its solo replay"
        )
    print(f"push_many: {len(ids)} coalesced streams bit-equal to solo "
          f"replays ({cfg.timesteps // chunk} gathered calls/window)")


if __name__ == "__main__":
    main()
