"""Quickstart: the paper end-to-end in two minutes on CPU.

1. Solve the balanced-II design for the paper's two FPGA targets (the DSE).
2. Train the small GW autoencoder on synthetic detector background.
3. Score signal vs background events (AUC) and stream-flag anomalies.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs.gw import GW_MODELS
from repro.core.balance import solve_min_ii
from repro.core.ii_model import DSP_TOTAL, GW_NOMINAL, GW_SMALL, U250, ZYNQ_7045
from repro.data.gw import GwDataConfig, GwDataset
from repro.serve.engine import AnomalyStreamEngine


def main():
    # -- 1. the paper's DSE: balanced reuse factors ------------------------
    for name, model, dev, total in [
        ("small AE  on Zynq7045", GW_SMALL, ZYNQ_7045, DSP_TOTAL["zynq7045"]),
        ("nominal AE on U250   ", GW_NOMINAL, U250, DSP_TOTAL["u250"]),
    ]:
        sol = solve_min_ii(model, total, dev, timesteps=8)
        d = sol.design
        print(f"{name}: R_h={d.reuse[0].r_h} R_x={d.reuse[0].r_x} "
              f"ii={sol.ii} cycles, DSP={d.dsp_used()}/{total}, "
              f"latency={d.latency_us(100 if dev is ZYNQ_7045 else 300):.3f} us")

    # -- 2. train the small autoencoder on background ----------------------
    from benchmarks.fig9_auc import evaluate_auc, train_autoencoder

    cfg = GW_MODELS["gw_small"]
    print("\ntraining gw_small autoencoder on synthetic background ...")
    params, losses, ds = train_autoencoder(cfg, steps=150, batch=32)
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    auc = evaluate_auc(params, cfg, ds, n=128)
    print(f"AUC (signal vs background): {auc:.3f}")

    # -- 3. stream scoring at a 1% FPR threshold ---------------------------
    engine = AnomalyStreamEngine(params, cfg)
    thr = engine.calibrate(ds.background(256), fpr=0.01)
    flags_bg = engine.flag(ds.background(128))
    flags_ev = engine.flag(ds.events(128))
    print(f"threshold={thr:.4f}: flagged {flags_bg.mean():.1%} of background "
          f"(target 1%), {flags_ev.mean():.1%} of injected events")


if __name__ == "__main__":
    main()
