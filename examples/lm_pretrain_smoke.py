"""LM pre-training driver: a ~100M-class transformer for a few hundred steps
through the full substrate (data -> model -> optimizer -> checkpoint).

Uses a trimmed smollm-360m (the assigned arch closest to the paper's small-
model regime) sized to run on this CPU container; on a real mesh the same
driver runs the full config via launch/train.py.

Run:  PYTHONPATH=src python examples/lm_pretrain_smoke.py [--steps 100]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.lm import LmDataConfig, lm_stream
from repro.models.api import get_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--ckpt", default="runs/lm_smoke_ckpt")
    args = ap.parse_args()

    base = get_arch(args.arch)
    # CPU-sized trim of the real config (layers/width cut, same family)
    cfg = dataclasses.replace(
        base, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab=2048, dtype=jnp.float32,
    )
    api = get_model(cfg)

    data_cfg = LmDataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    trainer = Trainer(
        loss_fn=lambda p, b: api.loss_fn(p, b, cfg),
        init_params_fn=lambda rng: api.init_params(rng, cfg),
        data_iter=(
            {k: jnp.asarray(v) for k, v in b.items()}
            for b in lm_stream(data_cfg)
        ),
        cfg=TrainerConfig(
            total_steps=args.steps, checkpoint_every=max(args.steps // 2, 1),
            opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
        ),
        ckpt_dir=args.ckpt,
    )
    result = trainer.run(jax.random.PRNGKey(0))
    first = float(np.mean(result.losses[:5]))
    last = float(np.mean(result.losses[-5:]))
    print(f"{args.arch} (trimmed): step {result.step}, "
          f"loss {first:.3f} -> {last:.3f} "
          f"(random baseline ~ log V = {np.log(cfg.vocab):.3f})")
    assert last < first, "loss must decrease"

    # a few greedy tokens through the serving engine (prefill+decode path)
    from repro.serve.engine import LmEngine

    eng = LmEngine(trainer.params, cfg, max_len=160)
    prompt = np.asarray([[1, 2, 3, 4]], np.int32)
    out = eng.generate(prompt, n_new=8)
    print("greedy continuation:", out[0].tolist())


if __name__ == "__main__":
    main()
